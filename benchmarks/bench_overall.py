"""Paper Fig. 10: latency / recall / memory for every method × θ × dataset.

The headline table: NAIVE (NLJ), INDEX, ES, ES+HWS (≈SIMJOIN), ES+SWS,
ES+MI, ES+MI+ADAPT. Memory = peak work-sharing cache entries (the paper's
online-memory metric; the index itself is offline, Fig. 13). Each row
carries the compressed-storage mode (``quant``) plus the distance-kernel
bytes moved per emitted pair, so an f32-vs-int8 sweep is
``run(quant_modes=("off", "sq8"))``.

``run_overlap`` is the wave-pipeline breakdown: the MI-join methods run
once with the double-buffered traversal⇆assembly overlap and once with
the sequential reference path, asserting the pair sets are identical and
reporting wall-clock plus the band-compacted re-rank's f32 gather bytes
per pair. ``run_early_exit`` is the PDX analogue: exit-on vs exit-off
wall-clock under ``pdx8`` on the clustered high-dim dataset, asserting
identical pair sets and reporting ``dims_scanned_frac``.
``run_trace_overhead`` is the TraceKit guard: the same cell min-of-N
timed with the span tracer off vs on, asserting identical pair sets and
that tracing costs < 5% wall-clock (plus a small additive slack for
sub-second CI cells). ``run_planner`` is the JoinPlanner parity table:
hand-tuned knobs vs ``plan_config``'s choice per dataset, asserting
admissibility (identical pair sets at a matching operating point;
soundness + no recall loss when calibration steers to a different one)
and zero cap-overflow retries at predicted caps (the ``--planner-only``
CI leg). ``run_sharded`` is the N-device
mesh sweep: per-shard-count wall-clock and per-transfer-class /
per-collective byte meters in forced-host-device subprocesses, asserting
host bytes per wave stay independent of N_y. ``--json PATH`` writes all
tables as a JSON artifact (``BENCH_overall.json``) — CI runs the
``--overlap-only`` form as a smoke step and the ``--sharded-only`` form
on the forced-8-device leg, and snapshots are committed at the repo root
so the perf trajectory survives between PRs alongside
``BENCH_offline.json``.
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import (REGIMES, SCALES, dist_bytes, emit,
                               run_method, theta_grid)

METHODS = ("nlj", "index", "es", "es_hws", "es_sws", "es_mi", "es_mi_adapt")


def run(scale: str = "ci", *, regimes=REGIMES, theta_idxs=(1, 3, 5, 7),
        methods=METHODS, quant_modes=("off",)) -> list[dict]:
    dim = SCALES[scale]["dim"]
    rows = []
    for regime in regimes:
        grid = theta_grid(regime, scale)
        for ti in theta_idxs:
            theta = grid[ti - 1]
            for method in methods:
                for quant in quant_modes:
                    res, dt, rec = run_method(regime, method, theta,
                                              scale=scale, quant=quant)
                    nbytes = dist_bytes(res, dim, quant)
                    rows.append(dict(
                        dataset=regime, theta_idx=ti, theta=theta,
                        method=method, quant=quant, seconds=dt, recall=rec,
                        pairs=len(res.pairs), n_dist=res.stats.n_dist,
                        n_rerank=res.stats.n_rerank,
                        bytes_per_pair=nbytes / max(len(res.pairs), 1),
                        cache_entries=res.stats.peak_cache_entries,
                        overflow=res.stats.n_overflow,
                        n_ood=res.stats.n_ood))
    return rows


def run_overlap(scale: str = "ci", *, regime: str = "manifold",
                theta_idx: int = 2,
                methods=("es_mi", "es_mi_adapt"),
                quant: str = "sq8") -> list[dict]:
    """MI-join wave-pipeline breakdown: overlap-on vs overlap-off
    wall-clock on identical configs, plus re-rank gather traffic.

    Each method cell runs both paths against the same cached indexes and
    asserts the emitted pair sets match bit-for-bit (``pairs_match``) —
    the pipeline is a pure scheduling change. ``rerank_bytes_per_pair``
    is the f32 traffic the band-compacted gather dispatched
    (``n_rerank_gather`` rows × d × 4B) amortized over emitted pairs:
    with compaction it tracks band occupancy, not pool capacity.
    """
    dim = SCALES[scale]["dim"]
    theta = theta_grid(regime, scale)[theta_idx - 1]
    rows = []
    for method in methods:
        cells = {}
        for overlap in (True, False):
            res, dt, rec = run_method(regime, method, theta, scale=scale,
                                      quant=quant, overlap=overlap)
            cells[overlap] = (res, dt, rec)
        res_on, dt_on, rec_on = cells[True]
        res_off, dt_off, _ = cells[False]
        match = res_on.pair_set() == res_off.pair_set()
        npairs = max(len(res_on.pairs), 1)
        rows.append(dict(
            dataset=regime, theta_idx=theta_idx, theta=theta,
            method=method, quant=quant,
            overlap_on_s=dt_on, overlap_off_s=dt_off,
            speedup=dt_off / max(dt_on, 1e-9),
            pairs=len(res_on.pairs), pairs_match=match,
            recall=rec_on, n_rerank=res_on.stats.n_rerank,
            rerank_gather=res_on.stats.n_rerank_gather,
            rerank_bytes_per_pair=(res_on.stats.n_rerank_gather * dim * 4
                                   / npairs),
            wait_s=res_on.stats.wait_seconds))
    return rows


def run_trace_overhead(scale: str = "ci", *, regime: str = "manifold",
                       theta_idx: int = 2, method: str = "es_mi",
                       quant: str = "sq8", repeats: int = 3,
                       slack_s: float = 0.15) -> list[dict]:
    """TraceKit overhead guard: one pipelined MI-join cell timed with the
    span tracer disabled vs enabled, min-of-``repeats`` per arm.

    Asserts (a) the emitted pair sets are bit-identical — tracing is
    observation, never scheduling — and (b) the traced arm's best
    wall-clock stays within 5% of the untraced best plus ``slack_s``
    seconds of additive slack (CI cells are sub-second, where a fixed 5%
    would be dominated by scheduler noise; the relative bound is what
    matters at paper scale).
    """
    from repro.obs import trace as obs_trace
    theta = theta_grid(regime, scale)[theta_idx - 1]

    def arm(traced: bool):
        times, res, n_events = [], None, 0
        for _ in range(repeats):
            tr = obs_trace.enable() if traced else None
            try:
                res, dt, _ = run_method(regime, method, theta, scale=scale,
                                        quant=quant)
            finally:
                if traced:
                    obs_trace.disable()
            if tr is not None:
                n_events = tr.n_events
            times.append(dt)
        return res, min(times), n_events

    res_off, t_off, _ = arm(False)
    res_on, t_on, n_events = arm(True)
    match = res_on.pair_set() == res_off.pair_set()
    assert match, (method, quant,
                   len(res_on.pair_set() ^ res_off.pair_set()))
    budget = 1.05 * t_off + slack_s
    assert t_on <= budget, (
        f"tracing overhead over budget: traced {t_on:.3f}s vs "
        f"untraced {t_off:.3f}s (budget {budget:.3f}s)")
    return [dict(
        dataset=regime, theta_idx=theta_idx, theta=theta,
        method=method, quant=quant,
        trace_off_s=t_off, trace_on_s=t_on,
        overhead_frac=(t_on - t_off) / max(t_off, 1e-9),
        trace_events=n_events,
        pairs=len(res_on.pairs), pairs_match=match)]


def run_early_exit(scale: str = "ci_hd", *, regime: str = "clustered",
                   theta_idx: int = 2,
                   methods=("nlj", "es_mi"),
                   quant: str = "pdx8") -> list[dict]:
    """PDX early-exit breakdown: exit-on vs exit-off (full slab scans)
    wall-clock on identical configs, on the clustered high-dim dataset
    where lanes actually retire early.

    Each method cell runs both paths and *asserts* the emitted pair sets
    match bit-for-bit (``pairs_match`` — the tail bound is certified, so
    exit is a pure wall-clock change); ``dims_scanned_frac`` is the
    fraction of candidate dimensions the slab kernels read with exit on
    (< 1.0 is the tier earning its keep; off reports exactly 1.0).
    """
    from repro.core.types import TraversalConfig
    dim = SCALES[scale]["dim"]
    theta = theta_grid(regime, scale)[theta_idx - 1]
    rows = []
    for method in methods:
        cells = {}
        for ee in (True, False):
            res, dt, rec = run_method(regime, method, theta, scale=scale,
                                      quant=quant,
                                      tcfg=TraversalConfig(early_exit=ee))
            cells[ee] = (res, dt, rec)
        res_on, dt_on, rec_on = cells[True]
        res_off, dt_off, _ = cells[False]
        match = res_on.pair_set() == res_off.pair_set()
        assert match, (method, quant,
                       len(res_on.pair_set() ^ res_off.pair_set()))
        rows.append(dict(
            dataset=regime, dim=dim, theta_idx=theta_idx, theta=theta,
            method=method, quant=quant,
            exit_on_s=dt_on, exit_off_s=dt_off,
            speedup=dt_off / max(dt_on, 1e-9),
            pairs=len(res_on.pairs), pairs_match=match,
            recall=rec_on,
            dims_scanned_frac=res_on.stats.dims_scanned_frac,
            dims_scanned_frac_off=res_off.stats.dims_scanned_frac,
            bytes_per_pair=(dist_bytes(res_on, dim, quant)
                            / max(len(res_on.pairs), 1))))
    return rows


def run_sharded(scale: str = "ci", *, regime: str = "manifold",
                theta_idx: int = 2, shard_counts=(1, 2, 4, 8),
                method: str = "es_mi", quant: str = "sq8",
                wave: int = 128) -> list[dict]:
    """N-device mesh driver vs single-device: wall-clock, per-transfer-
    class bytes (feedback / band / assembly), per-collective bytes
    (all_gather / ppermute / psum), and ``shard_band_imbalance``
    (max/mean ambiguous-band occupancy across shards).

    Each shard count runs in a subprocess with that many forced host
    devices (jax locks the device count at first init). Two extra ``nlj``
    cells run the same shard count and θ at N_y and 4·N_y and *assert*
    host bytes per wave stay sub-linear in N_y (< 2× for 4× rows): the
    on-device pool merge ships only the band-compacted merged pool
    (S × B × merge_cap int32), so host traffic tracks band occupancy,
    not the data-side row count.
    """
    import os
    import subprocess
    import sys

    from repro.data.vectors import make_dataset, thresholds

    n_data = 8_000 if scale == "ci" else 60_000
    n_query, dim = (256, 48) if scale == "ci" else (1_000, 96)
    ref = make_dataset(regime, n_data=n_data, n_query=n_query, dim=dim,
                       seed=5)
    theta = float(thresholds(ref, 7)[theta_idx - 1])

    def cell(n_shards, *, n_data=n_data, method=method, quant=quant):
        env = dict(os.environ, REPRO_BENCH_DEVICES=str(max(n_shards, 1)),
                   PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks._sharded_worker",
             "--n-data", str(n_data), "--n-query", str(n_query),
             "--dim", str(dim), "--shards", str(n_shards),
             "--method", method, "--quant", quant,
             "--theta", repr(theta), "--wave", str(wave)],
            capture_output=True, text=True, env=env, check=True)
        return json.loads(out.stdout.strip().splitlines()[-1])

    rows = [cell(s) for s in shard_counts]
    base_s = rows[0]["seconds"]
    for r in rows:
        r["speedup_vs_1"] = base_s / max(r["seconds"], 1e-9)

    # host-bytes-per-wave independence of N_y: same shards, same θ, the
    # pure mesh NLJ driver (host traffic == the merged pool transfer)
    s_chk = max(s for s in shard_counts if s > 1)
    small = cell(s_chk, n_data=n_data // 2, method="nlj", quant="off")
    big = cell(s_chk, n_data=2 * n_data, method="nlj", quant="off")
    ratio = big["host_bytes_per_wave"] / max(small["host_bytes_per_wave"],
                                             1e-9)
    assert ratio < 2.0, (
        f"host bytes per wave grew {ratio:.2f}x for 4x N_y "
        f"({small['host_bytes_per_wave']:.0f} -> "
        f"{big['host_bytes_per_wave']:.0f}B): the pool merge is leaking "
        f"N_y-proportional traffic to the host")
    for r in (small, big):
        r["speedup_vs_1"] = float("nan")
        r["ny_check"] = True
        r["host_bytes_ratio"] = ratio
    return rows + [small, big]


def run_planner(scale: str = "ci", *, regimes=REGIMES, theta_idx: int = 2,
                method: str = "es_mi", quant: str = "sketch8",
                wave: int = 128) -> list[dict]:
    """JoinPlanner parity table: hand-tuned knobs vs the planner's
    choice, per dataset.

    The hand arm runs the fixed (method, quant, wave) cell through
    ``run_method`` (which also calibrates the persistent engine's cost
    table); the planned arm asks ``JoinEngine.plan_config`` for the
    operating point — with calibrated candidates, the planner picks by
    measured cost — warms that exact config, then times it.

    Admissibility is asserted per what the planner was free to change:
    when it lands on the hand arm's (method, quant), the pair sets must
    be bit-identical (knobs like wave size and cap seeds are advisory —
    they move wall-clock, never pairs); when it picks a *different*
    operating point (e.g. exact NLJ once calibration shows it cheaper
    than an approximate traversal), set identity is the wrong bar —
    instead the planned arm must be sound (⊆ exact truth) and lose no
    recall vs the hand arm. Both arms must take **zero** cap-overflow
    retries at the predicted caps (``JoinStats.overflow_retries``).
    """
    from benchmarks.common import dataset, engine, truth
    from repro.core.types import JoinConfig, recall as _recall

    rows = []
    for regime in regimes:
        theta = theta_grid(regime, scale)[theta_idx - 1]
        res_h, dt_h, rec_h = run_method(regime, method, theta,
                                        scale=scale, quant=quant,
                                        wave=wave)
        ds = dataset(regime, scale)
        eng = engine(regime, scale)
        cfg_p = eng.plan_config(
            ds.X, JoinConfig(method=method, theta=theta, quant=quant,
                             wave_size=wave))
        # warm the planned cell so its timing is compile-free like the
        # hand arm's (run_method warms + re-calibrates as a side effect)
        run_method(regime, cfg_p.method, theta, scale=scale,
                   quant=cfg_p.quant, wave=cfg_p.wave_size)
        t0 = time.perf_counter()
        res_p = eng.join(ds.X, cfg_p)
        dt_p = time.perf_counter() - t0
        same_point = (cfg_p.method, cfg_p.quant) == (method, quant)
        match = res_p.pair_set() == res_h.pair_set()
        rec_p = _recall(res_p, truth(regime, theta, scale))
        tset = set(map(tuple, truth(regime, theta, scale).tolist()))
        sound = not (res_p.pair_set() - tset)
        admissible = (match if same_point
                      else (sound and rec_p >= rec_h - 1e-9))
        assert admissible, (
            f"{regime}: planned ({cfg_p.method}/{cfg_p.quant}) vs "
            f"hand-tuned ({method}/{quant}): "
            + (f"pair sets differ by "
               f"{len(res_p.pair_set() ^ res_h.pair_set())}"
               if same_point else
               f"sound={sound} recall {rec_p:.4f} < {rec_h:.4f}"))
        assert res_p.stats.overflow_retries == 0, (
            f"{regime}: planned run took "
            f"{res_p.stats.overflow_retries} cap-overflow retries at "
            f"predicted caps")
        plan = eng.planner.plan(
            ds.X, theta=theta,
            pool_cap=int(cfg_p.traversal.pool_cap),
            n_shards=eng.n_shards, dim=int(ds.Y.shape[1]))
        rows.append(dict(
            dataset=regime, theta_idx=theta_idx, theta=theta,
            hand_method=method, hand_quant=quant, hand_wave=wave,
            planned_method=cfg_p.method, planned_quant=cfg_p.quant,
            planned_wave=cfg_p.wave_size, plan_source=plan.source,
            hand_s=dt_h, planned_s=dt_p,
            speedup=dt_h / max(dt_p, 1e-9),
            pairs=len(res_p.pairs), same_point=same_point,
            pairs_match=match, admissible=admissible,
            recall=rec_h, planned_recall=rec_p,
            predicted_pairs=plan.predicted_join_size,
            hand_retries=res_h.stats.overflow_retries,
            planned_retries=res_p.stats.overflow_retries))
    return rows


def run_serve(scale: str = "ci", *, regimes=("manifold", "clustered"),
              theta_idx: int = 2, n_requests: int = 16,
              quant_modes=("off", "sq8"), method: str = "es_sws",
              buckets=(64, 128), seed: int = 0) -> list[dict]:
    """JoinService admission-path benchmark: one multi-tenant shuffled
    request stream through the continuous-batching front end.

    Reports admission latency (mean / max over the stream), serving
    throughput (queries/s after warmup), wave-lane occupancy, and the
    XLA compile-counter delta across the serving phase — asserted flat,
    the bucket-ladder invariant the front end exists to provide.
    """
    import numpy as np

    from benchmarks.common import dataset
    from repro.obs import metrics as obs_metrics
    from repro.serve import JoinRequest, JoinService, ServiceConfig

    dim = SCALES[scale]["dim"]
    svc = JoinService(ServiceConfig(buckets=tuple(buckets),
                                    max_queue=4 * n_requests))
    tenants = {}
    for i, regime in enumerate(regimes):
        ds = dataset(regime, scale)
        theta = theta_grid(regime, scale)[theta_idx - 1]
        svc.load(regime, ds.Y)
        tenants[regime] = (ds, theta)
    t0 = time.perf_counter()
    for regime, (ds, theta) in tenants.items():
        svc.warmup(regime, thetas=[theta], methods=(method,),
                   quants=quant_modes)
    warm_s = time.perf_counter() - t0

    rng = np.random.default_rng(seed)
    names = list(tenants)
    for uid in range(n_requests):
        regime = names[int(rng.integers(len(names)))]
        ds, theta = tenants[regime]
        n_max = int(ds.X.shape[0])
        n = int(rng.integers(1, min(2 * max(buckets), n_max) + 1))
        lo = int(rng.integers(0, n_max - n + 1))
        svc.submit(JoinRequest(
            uid=uid, tenant=regime,
            X=np.asarray(ds.X, np.float32)[lo:lo + n], theta=theta,
            method=method, quant=quant_modes[uid % len(quant_modes)]))
    c0 = obs_metrics.compile_count()
    t0 = time.perf_counter()
    done = svc.run()
    dt = time.perf_counter() - t0
    compiles = obs_metrics.compile_count() - c0
    assert compiles == 0, (
        f"{compiles} recompiles in steady-state serving (bucket ladder "
        f"not warm)")
    served = [sj for sj in done.values() if sj.ok]
    n_queries = sum(sj.n_queries for sj in served)
    h = svc.metrics.get("serve_join.admission_seconds")
    occ = svc.metrics.get("serve_join.occupancy")
    return [dict(
        scale=scale, method=method, tenants=len(tenants),
        requests=len(served), queries=n_queries,
        pairs=sum(len(sj.pairs) for sj in served),
        warmup_s=warm_s, serve_s=dt,
        queries_per_s=n_queries / max(dt, 1e-9),
        admission_mean_s=h.sum / max(h.count, 1),
        occupancy_mean=occ.sum / max(occ.count, 1),
        serve_compiles=compiles)]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="ci")
    ap.add_argument("--regimes", nargs="*", default=list(REGIMES))
    ap.add_argument("--overlap-only", action="store_true",
                    help="run only the wave-pipeline and early-exit "
                         "breakdowns (the CI smoke configuration)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="run only the N-device mesh sweep (the CI "
                         "forced-8-device leg)")
    ap.add_argument("--planner-only", action="store_true",
                    help="run only the JoinPlanner parity table "
                         "(planned vs hand-tuned knobs; the CI planner "
                         "leg — asserts identical pairs and zero "
                         "overflow retries at predicted caps)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + metadata as a JSON artifact "
                         "(e.g. BENCH_overall.json for the CI upload)")
    args = ap.parse_args(argv)
    if args.sharded_only:
        sharded_rows = run_sharded(args.scale, regime=args.regimes[0])
        emit(sharded_rows)
        if args.json:
            payload = dict(bench="overall", scale=args.scale,
                           sharded=sharded_rows)
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"# wrote {args.json}")
        return
    if args.planner_only:
        planner_rows = run_planner(args.scale,
                                   regimes=tuple(args.regimes))
        emit(planner_rows)
        if args.json:
            payload = dict(bench="overall", scale=args.scale,
                           planner=planner_rows)
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"# wrote {args.json}")
        return
    rows = ([] if args.overlap_only
            else run(args.scale, regimes=tuple(args.regimes)))
    overlap_rows = run_overlap(args.scale, regime=args.regimes[0])
    early_exit_rows = run_early_exit(
        "full_hd" if args.scale == "full" else "ci_hd")
    trace_rows = run_trace_overhead(args.scale, regime=args.regimes[0])
    serve_rows = run_serve(args.scale)
    planner_rows = ([] if args.overlap_only
                    else run_planner(args.scale,
                                     regimes=tuple(args.regimes)))
    sharded_rows = ([] if args.overlap_only
                    else run_sharded(args.scale, regime=args.regimes[0]))
    emit(rows)
    emit(overlap_rows)
    emit(early_exit_rows)
    emit(trace_rows)
    emit(serve_rows)
    emit(planner_rows)
    emit(sharded_rows)
    if args.json:
        payload = dict(bench="overall", scale=args.scale, rows=rows,
                       overlap=overlap_rows, early_exit=early_exit_rows,
                       trace_overhead=trace_rows, serve=serve_rows,
                       planner=planner_rows, sharded=sharded_rows)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
