"""Paper Fig. 10: latency / recall / memory for every method × θ × dataset.

The headline table: NAIVE (NLJ), INDEX, ES, ES+HWS (≈SIMJOIN), ES+SWS,
ES+MI, ES+MI+ADAPT. Memory = peak work-sharing cache entries (the paper's
online-memory metric; the index itself is offline, Fig. 13). Each row
carries the compressed-storage mode (``quant``) plus the distance-kernel
bytes moved per emitted pair, so an f32-vs-int8 sweep is
``run(quant_modes=("off", "sq8"))``.
"""
from __future__ import annotations

from benchmarks.common import (REGIMES, SCALES, dist_bytes, emit,
                               run_method, theta_grid)

METHODS = ("nlj", "index", "es", "es_hws", "es_sws", "es_mi", "es_mi_adapt")


def run(scale: str = "ci", *, regimes=REGIMES, theta_idxs=(1, 3, 5, 7),
        methods=METHODS, quant_modes=("off",)) -> list[dict]:
    dim = SCALES[scale]["dim"]
    rows = []
    for regime in regimes:
        grid = theta_grid(regime, scale)
        for ti in theta_idxs:
            theta = grid[ti - 1]
            for method in methods:
                for quant in quant_modes:
                    res, dt, rec = run_method(regime, method, theta,
                                              scale=scale, quant=quant)
                    nbytes = dist_bytes(res, dim, quant)
                    rows.append(dict(
                        dataset=regime, theta_idx=ti, theta=theta,
                        method=method, quant=quant, seconds=dt, recall=rec,
                        pairs=len(res.pairs), n_dist=res.stats.n_dist,
                        n_rerank=res.stats.n_rerank,
                        bytes_per_pair=nbytes / max(len(res.pairs), 1),
                        cache_entries=res.stats.peak_cache_entries,
                        overflow=res.stats.n_overflow,
                        n_ood=res.stats.n_ood))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
