import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + (
    os.environ.get("REPRO_BENCH_DEVICES", "4"))

# Worker for bench_overall.run_sharded: needs N forced host devices, so it
# must own the process (jax locks the device count at first init). Runs one
# (n_shards, method, quant, N_y) cell through the mesh-driver JoinEngine
# twice — the first pass pays index builds and compiles, the second is the
# reported steady-state wall-clock — and prints one JSON line with the
# per-transfer-class and per-collective byte meters.
import argparse
import json
import time

import numpy as np

from repro.core import exact_join_pairs
from repro.core.types import JoinConfig, JoinResult, JoinStats, recall
from repro.data.vectors import make_dataset
from repro.engine import JoinEngine


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-data", type=int, required=True)
    ap.add_argument("--n-query", type=int, default=256)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--shards", type=int, required=True)
    ap.add_argument("--method", default="es_mi")
    ap.add_argument("--quant", default="off")
    ap.add_argument("--theta", type=float, required=True)
    ap.add_argument("--wave", type=int, default=128)
    args = ap.parse_args(argv)

    ds = make_dataset("manifold", n_data=args.n_data, n_query=args.n_query,
                      dim=args.dim, seed=5)
    cfg = JoinConfig(method=args.method, theta=args.theta,
                     wave_size=args.wave, quant=args.quant)
    eng = JoinEngine(ds.Y, build_kw=dict(k=32, degree=24),
                     n_shards=args.shards)
    eng.join(ds.X, cfg)  # builds + compiles
    t0 = time.perf_counter()
    res = eng.join(ds.X, cfg)
    dt = time.perf_counter() - t0

    tr = exact_join_pairs(ds.X, ds.Y, args.theta)
    rec = recall(JoinResult(pairs=res.pairs, stats=JoinStats()), tr)
    st = res.stats
    occ = np.asarray(st.band_occ_per_shard or (0,), dtype=np.float64)
    n_waves = max(-(-args.n_query // args.wave), 1)
    host_bytes = st.bytes_feedback + st.bytes_band + st.bytes_assembly
    print(json.dumps(dict(
        n_shards=args.shards, n_data=args.n_data, method=args.method,
        quant=args.quant, seconds=dt, recall=rec, pairs=len(res.pairs),
        n_dist=int(st.n_dist),
        bytes_feedback=int(st.bytes_feedback),
        bytes_band=int(st.bytes_band),
        bytes_assembly=int(st.bytes_assembly),
        bytes_allgather=int(st.bytes_allgather),
        bytes_ppermute=int(st.bytes_ppermute),
        bytes_psum=int(st.bytes_psum),
        host_bytes_per_wave=host_bytes / n_waves,
        shard_band_imbalance=(float(occ.max() / occ.mean())
                              if occ.mean() > 0 else 1.0))))


if __name__ == "__main__":
    main()
