"""Paper Fig. 15: index-type ablation — NSG (RNG-pruned) vs NSW-style
(unpruned kNN graph; the flat stand-in for HNSW, DESIGN §2) on one ID and
one OOD dataset."""
from __future__ import annotations

from benchmarks.common import emit, run_method, theta_grid

METHODS = ("index", "es", "es_sws", "es_mi", "es_mi_adapt")


def run(scale: str = "ci", *, regimes=("manifold", "ood")) -> list[dict]:
    rows = []
    for regime in regimes:
        theta = theta_grid(regime, scale)[0]
        for style in ("nsg", "nsw"):
            for method in METHODS:
                res, dt, rec = run_method(regime, method, theta,
                                          scale=scale, style=style)
                rows.append(dict(dataset=regime, index=style, method=method,
                                 seconds=dt, recall=rec,
                                 n_dist=res.stats.n_dist))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
