"""Shared benchmark scaffolding.

Every bench_*.py exposes ``run(scale: str) -> list[dict]`` ("ci" = minutes
on CPU, "full" = the paper-scale sweep) and prints CSV via ``emit``.
Datasets mirror the paper's Table-1 regimes (data/vectors.py); one
``JoinEngine`` per (dataset, scale, style) holds the indexes, so they are
built once and reused across benches, methods, and thresholds within a
process.
"""
from __future__ import annotations

import functools
import sys
import time

import numpy as np

from repro.core import exact_join_pairs
from repro.core.join import vector_join
from repro.core.types import JoinConfig, JoinResult, TraversalConfig, recall
from repro.data.vectors import VectorDataset, make_dataset, thresholds
from repro.engine import JoinEngine

# the paper's eight datasets → four synthetic regimes (DESIGN §7)
REGIMES = ("manifold", "weak", "clustered", "ood")

SCALES = {
    "ci": dict(n_data=12_000, n_query=384, dim=48),
    "full": dict(n_data=100_000, n_query=2_000, dim=96),
    # high-dim cells for the quantized-storage comparison (d ≥ 256 is
    # where bytes-per-distance dominates the join)
    "ci_hd": dict(n_data=4_000, n_query=128, dim=256),
    "full_hd": dict(n_data=50_000, n_query=1_000, dim=512),
}


@functools.cache
def dataset(regime: str, scale: str = "ci", seed: int = 0) -> VectorDataset:
    kw = SCALES[scale]
    return make_dataset(regime, seed=seed, **kw)


@functools.cache
def theta_grid(regime: str, scale: str = "ci", n: int = 7
               ) -> tuple[float, ...]:
    return tuple(float(t) for t in thresholds(dataset(regime, scale), n))


_ENGINES: dict = {}


def engine(regime: str, scale: str = "ci", *, k: int = 32, degree: int = 24,
           style: str = "nsg") -> JoinEngine:
    """The persistent serving object every bench cell runs through (one per
    (dataset, build recipe), keyed explicitly so every call-site spelling
    hits the same instance). Because the engine persists, so does its
    planner calibration: every cell a bench runs feeds
    ``JoinEngine.cost_table`` (fastest-per-query wins, so warmup compile
    time never sticks), and later planner-driven cells reuse that one
    measurement instead of re-measuring — exported per engine via
    ``metrics_snapshot()['cost_table']`` / ``cost_table()`` below."""
    key = (regime, scale, k, degree, style)
    if key not in _ENGINES:
        ds = dataset(regime, scale)
        _ENGINES[key] = JoinEngine(
            ds.Y, build_kw=dict(k=k, degree=degree, style=style))
    return _ENGINES[key]


def cost_table(regime: str, scale: str = "ci", *, k: int = 32,
               degree: int = 24, style: str = "nsg") -> dict:
    """The persistent engine's warmup-calibrated planner cost table
    (``{"method/quant": per-unit costs}``; empty before any join ran)."""
    return engine(regime, scale, k=k, degree=degree,
                  style=style).metrics_snapshot().get("cost_table", {})


def indexes(regime: str, scale: str = "ci", *, k: int = 32, degree: int = 24,
            style: str = "nsg"):
    """(G_Y, G_X, G_{X∪Y}) built through the engine's cache."""
    ds = dataset(regime, scale)
    eng = engine(regime, scale, k=k, degree=degree, style=style)
    return eng.index_y(), eng.index_x(ds.X), eng.merged_index(ds.X)


@functools.cache
def truth(regime: str, theta: float, scale: str = "ci") -> np.ndarray:
    ds = dataset(regime, scale)
    return exact_join_pairs(ds.X, ds.Y, theta)


_WARMED: set = set()


def run_method(regime: str, method: str, theta: float, *, scale: str = "ci",
               tcfg: TraversalConfig | None = None, wave: int = 128,
               style: str = "nsg", quant: str = "off",
               overlap: bool = True
               ) -> tuple[JoinResult, float, float]:
    """(result, seconds, recall) for one (dataset, method, θ) cell."""
    ds = dataset(regime, scale)
    eng = engine(regime, scale, style=style)
    cfg = JoinConfig(method=method, theta=theta, wave_size=wave,
                     traversal=tcfg or TraversalConfig(), quant=quant,
                     overlap=overlap)
    # warm the jit caches (keyed on wave shape + traversal config) with a
    # query subset so reported latency is compile-free, like the paper's
    # steady-state measurements. The warm-up runs through a *transient*
    # engine (vector_join) with the prebuilt full-X indexes: jit caches
    # are process-global, and the persistent engine's per-X cache must not
    # learn full-X artifacts under the subset's fingerprint.
    wkey = (regime, method, scale, style, cfg.traversal, wave, quant)
    if wkey not in _WARMED:
        if method != "nlj":
            iy, ix, im = indexes(regime, scale, style=style)
            vector_join(ds.X[:32], ds.Y, cfg, index_y=iy, index_x=ix,
                        index_merged=im)
        # pre-build the persistent engine's QuantStore artifact too (the
        # transient warm-up engine's store dies with it) so the timed
        # join is compile- and build-free for sq8 exactly as it is for
        # f32
        eng.warm_quant(ds.X, cfg)
        _WARMED.add(wkey)
    t0 = time.perf_counter()
    res = eng.join(ds.X, cfg)
    dt = time.perf_counter() - t0
    rec = recall(res, truth(regime, theta, scale))
    return res, dt, rec


# Per-candidate sketch-tier bytes beyond the packed codes: the two
# slack-table entries (checkpoint at h + norm) the bound reads
# (sketch_lower_bound_gather; see quant/sketch.py).
SKETCH_META_BYTES = 8


def dist_bytes(res: JoinResult, dim: int, quant: str) -> int:
    """Distance-kernel bytes moved for one join (the C4 hot-spot traffic
    model): each counted distance streams one d-dim candidate row —
    d×4 bytes from the f32 table, d×1 from int8 codes, d/8 + slack-table
    bytes from 1-bit sketches — plus d×1 per int8 escalation (sketch8)
    and d×4 per exact re-rank evaluation."""
    if quant == "sketch8":
        return (res.stats.n_dist * (dim // 8 + SKETCH_META_BYTES)
                + res.stats.n_esc8 * dim + res.stats.n_rerank * dim * 4)
    if quant in ("pdx8", "sketchpdx8"):
        # PDX lanes stop reading at retirement: scale the slab traffic
        # (int8 filter rows and f32 re-rank rows alike) by the fraction
        # of dimensions actually scanned
        frac = res.stats.dims_scanned_frac
        filt = (res.stats.n_dist * (dim // 8 + SKETCH_META_BYTES)
                + res.stats.n_esc8 * dim * frac
                if quant == "sketchpdx8" else res.stats.n_dist * dim * frac)
        return int(filt + res.stats.n_rerank * dim * 4 * frac)
    per_dist = dim * (1 if quant == "sq8" else 4)
    return res.stats.n_dist * per_dist + res.stats.n_rerank * dim * 4


def emit(rows: list[dict], *, file=None) -> None:
    """Print rows as CSV (keys of the first row define the header)."""
    file = file or sys.stdout
    if not rows:
        return
    keys = list(rows[0].keys())
    print(",".join(keys), file=file)
    for r in rows:
        print(",".join(_fmt(r.get(k)) for k in keys), file=file)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
