"""Beyond paper: the distributed (shard_map) MI join.

Verifies X ⋈ Y == ∪ₛ (X ⋈ Yₛ) numerically — recall must be
shard-count-independent — and reports per-wave throughput. Each shard
count runs in a subprocess with that many forced host devices (one shard
per device, as on the production mesh; the in-process mesh here has a
single CPU device). The production-mesh version is exercised by the
dry-run join cells (launch/dryrun.py --join).
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit


def run(scale: str = "ci") -> list[dict]:
    n = 8_000 if scale == "ci" else 100_000
    rows = []
    for n_shards in (1, 2, 4):
        env = dict(os.environ, REPRO_BENCH_DEVICES=str(n_shards),
                   PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks._distributed_worker",
             str(n), str(n_shards)],
            capture_output=True, text=True, env=env, check=True)
        line = out.stdout.strip().splitlines()[-1]
        s, dt, rec, pairs, nd = line.split(",")
        rows.append(dict(n_shards=int(s), seconds=float(dt),
                         recall=float(rec), pairs=int(pairs),
                         n_dist=int(nd)))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
