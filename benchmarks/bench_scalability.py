"""Paper Fig. 14: scalability in |Y| at the smallest threshold θ₁."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import (build_index, build_merged_index, exact_join_pairs,
                        recall)
from repro.core.join import vector_join
from repro.core.types import JoinConfig
from repro.data.vectors import make_dataset, thresholds

METHODS = ("nlj", "es", "es_sws", "es_mi")
SIZES_CI = (4_000, 8_000, 16_000, 32_000)
SIZES_FULL = (10_000, 100_000, 1_000_000)


def run(scale: str = "ci") -> list[dict]:
    sizes = SIZES_CI if scale == "ci" else SIZES_FULL
    rows = []
    for n in sizes:
        ds = make_dataset("manifold", n_data=n, n_query=256, dim=48, seed=3)
        theta = float(thresholds(ds, 7)[0])
        iy = build_index(ds.Y, k=32, degree=24)
        ix = build_index(ds.X, k=32, degree=24)
        im = build_merged_index(ds.Y, ds.X, k=32, degree=24)
        tr = exact_join_pairs(ds.X, ds.Y, theta)
        for method in METHODS:
            cfg = JoinConfig(method=method, theta=theta, wave_size=128)
            t0 = time.perf_counter()
            res = vector_join(ds.X, ds.Y, cfg, index_y=iy, index_x=ix,
                              index_merged=im)
            dt = time.perf_counter() - t0
            rows.append(dict(n_data=n, method=method, seconds=dt,
                             recall=recall(res, tr),
                             n_dist=res.stats.n_dist))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
