"""Paper Fig. 14: scalability in |Y| at the smallest threshold θ₁."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import exact_join_pairs, recall
from repro.core.types import JoinConfig
from repro.data.vectors import make_dataset, thresholds
from repro.engine import JoinEngine

METHODS = ("nlj", "es", "es_sws", "es_mi")
SIZES_CI = (4_000, 8_000, 16_000, 32_000)
SIZES_FULL = (10_000, 100_000, 1_000_000)


def run(scale: str = "ci") -> list[dict]:
    sizes = SIZES_CI if scale == "ci" else SIZES_FULL
    rows = []
    for n in sizes:
        ds = make_dataset("manifold", n_data=n, n_query=256, dim=48, seed=3)
        theta = float(thresholds(ds, 7)[0])
        eng = JoinEngine(ds.Y, build_kw=dict(k=32, degree=24))
        eng.index_y(), eng.index_x(ds.X), eng.merged_index(ds.X)  # offline
        tr = exact_join_pairs(ds.X, ds.Y, theta)
        for method in METHODS:
            cfg = JoinConfig(method=method, theta=theta, wave_size=128)
            t0 = time.perf_counter()
            res = eng.join(ds.X, cfg)
            dt = time.perf_counter() - t0
            rows.append(dict(n_data=n, method=method, seconds=dt,
                             recall=recall(res, tr),
                             n_dist=res.stats.n_dist))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
