"""Paper Fig. 13: offline overhead — separate query+data indexes vs the
merged index (size and build time)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import REGIMES, dataset, emit
from repro.core import build_index, build_merged_index


def _index_bytes(gi) -> int:
    return (np.asarray(gi.vecs).nbytes + np.asarray(gi.nbrs).nbytes
            + np.asarray(gi.mean_nbr_dist).nbytes)


def run(scale: str = "ci", *, regimes=REGIMES) -> list[dict]:
    rows = []
    for regime in regimes:
        ds = dataset(regime, scale)
        t0 = time.perf_counter()
        iy = build_index(ds.Y, k=32, degree=24)
        ix = build_index(ds.X, k=32, degree=24)
        t_sep = time.perf_counter() - t0
        t0 = time.perf_counter()
        im = build_merged_index(ds.Y, ds.X, k=32, degree=24)
        t_merged = time.perf_counter() - t0
        sep = _index_bytes(iy) + _index_bytes(ix)
        mrg = _index_bytes(im)
        rows.append(dict(
            dataset=regime, sep_build_s=t_sep, merged_build_s=t_merged,
            sep_bytes=sep, merged_bytes=mrg, size_ratio=mrg / sep,
            time_ratio=t_merged / t_sep))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
