"""Paper Fig. 13: offline overhead — separate query+data indexes vs the
merged index (size and build time) — plus the FilterCascade build
comparison: the same ``build_index`` driven through certified int8
bounds (``quant="sq8"``), which must produce *identical* neighbor lists
while moving a fraction of the f32 bytes through the construction
distance sweeps (``core.graph.BuildStats``).

``--json PATH`` additionally writes the rows (plus metadata) as a JSON
artifact — CI runs this as a smoke step and uploads ``BENCH_offline.json``
so the offline-build perf trajectory is recorded per commit.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import REGIMES, dataset, emit
from repro.core import build_index, build_merged_index
from repro.core.graph import BuildStats


def _index_bytes(gi) -> int:
    return (np.asarray(gi.vecs).nbytes + np.asarray(gi.nbrs).nbytes
            + np.asarray(gi.mean_nbr_dist).nbytes)


def run(scale: str = "ci", *, regimes=REGIMES) -> list[dict]:
    rows = []
    for regime in regimes:
        ds = dataset(regime, scale)
        t0 = time.perf_counter()
        iy = build_index(ds.Y, k=32, degree=24)
        ix = build_index(ds.X, k=32, degree=24)
        t_sep = time.perf_counter() - t0
        t0 = time.perf_counter()
        im = build_merged_index(ds.Y, ds.X, k=32, degree=24)
        t_merged = time.perf_counter() - t0
        sep = _index_bytes(iy) + _index_bytes(ix)
        mrg = _index_bytes(im)
        # cascade-driven build of G_Y: identical edges, f32 traffic cut
        # to the ambiguous band (per-tier survivor counts in BuildStats)
        bs = BuildStats()
        t0 = time.perf_counter()
        iyq = build_index(ds.Y, k=32, degree=24, quant="sq8",
                          build_stats=bs)
        t_casc = time.perf_counter() - t0
        edges_match = bool(
            np.array_equal(np.asarray(iy.nbrs), np.asarray(iyq.nbrs)))
        rows.append(dict(
            dataset=regime, sep_build_s=t_sep, merged_build_s=t_merged,
            sep_bytes=sep, merged_bytes=mrg, size_ratio=mrg / sep,
            time_ratio=t_merged / t_sep,
            cascade_build_s=t_casc, edges_match=edges_match,
            f32_bytes=bs.f32_bytes, f32_bytes_full=bs.f32_bytes_full,
            f32_saved_frac=bs.f32_saved_frac, tier_bytes=bs.tier_bytes,
            knn_pairs=bs.knn_pairs, knn_exact=bs.knn_exact,
            prune_pairs=bs.prune_pairs, prune_exact=bs.prune_exact))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="ci")
    ap.add_argument("--regimes", nargs="*", default=list(REGIMES))
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows + metadata as a JSON artifact "
                         "(e.g. BENCH_offline.json for the CI upload)")
    args = ap.parse_args(argv)
    rows = run(args.scale, regimes=tuple(args.regimes))
    emit(rows)
    if args.json:
        payload = dict(bench="offline", scale=args.scale, rows=rows)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
