import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + (
    os.environ.get("REPRO_BENCH_DEVICES", "4"))

# Worker for bench_distributed: needs N host devices, so it must own the
# process (jax locks the device count at first init). Prints one CSV row.
import sys
import time

import jax

from repro.core import exact_join_pairs, recall
from repro.core.distributed import (build_sharded_merged_index,
                                    distributed_mi_join)
from repro.core.types import JoinResult, JoinStats, TraversalConfig
from repro.data.vectors import make_dataset, thresholds


def main(n_data: int, n_shards: int) -> None:
    ds = make_dataset("manifold", n_data=n_data, n_query=256, dim=48, seed=5)
    theta = float(thresholds(ds, 7)[1])
    tr = exact_join_pairs(ds.X, ds.Y, theta)
    mesh = jax.make_mesh((n_shards,), ("data",))
    smi = build_sharded_merged_index(ds.Y, ds.X, n_shards, k=32, degree=24)
    t0 = time.perf_counter()
    pairs, stats = distributed_mi_join(
        ds.X, smi, mesh, ("data",), theta=theta, cfg=TraversalConfig(),
        wave_size=128)
    dt = time.perf_counter() - t0
    rec = recall(JoinResult(pairs=pairs, stats=JoinStats()), tr)
    print(f"{n_shards},{dt:.6g},{rec:.6g},{len(pairs)},{stats.n_dist}")


if __name__ == "__main__":
    main(int(sys.argv[1]), int(sys.argv[2]))
