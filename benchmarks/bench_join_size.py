"""Paper Fig. 9: join size per dataset and threshold (exact NLJ counts)."""
from __future__ import annotations

from benchmarks.common import REGIMES, dataset, emit, theta_grid, truth


def run(scale: str = "ci") -> list[dict]:
    rows = []
    for regime in REGIMES:
        ds = dataset(regime, scale)
        denom = ds.X.shape[0] * ds.Y.shape[0]
        for i, theta in enumerate(theta_grid(regime, scale), 1):
            n = len(truth(regime, theta, scale))
            rows.append(dict(dataset=regime, theta_idx=i, theta=theta,
                             join_size=n, selectivity=n / denom))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
