"""Benchmark runner — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale ci|full] [--only NAME]

Sections map to the paper (DESIGN §7): Fig 9 join sizes, Fig 10 overall,
Fig 11 queue sizes, Fig 12 breakdown, Fig 13 offline overhead, Fig 14
scalability, Fig 15 index type, plus the beyond-paper distributed join.
"""
from __future__ import annotations

import argparse
import time
import traceback

from types import SimpleNamespace

from benchmarks import (bench_breakdown, bench_distributed, bench_index_type,
                        bench_join_size, bench_offline, bench_overall,
                        bench_queue_size, bench_scalability)
from benchmarks.common import emit

_quant = SimpleNamespace(run=lambda scale: bench_breakdown.run_quant(
    "full_hd" if scale == "full" else "ci_hd"))

BENCHES = [
    ("fig9_join_size", bench_join_size),
    ("fig10_overall", bench_overall),
    ("fig11_queue_size", bench_queue_size),
    ("fig12_breakdown", bench_breakdown),
    ("quant_bytes", _quant),           # f32-vs-sq8 kernel time & bytes
    ("fig13_offline", bench_offline),
    ("fig14_scalability", bench_scalability),
    ("fig15_index_type", bench_index_type),
    ("distributed_join", bench_distributed),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=("ci", "full"), default="ci")
    ap.add_argument("--only")
    args = ap.parse_args(argv)
    failed = []
    for name, mod in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n== {name} (scale={args.scale}) ==", flush=True)
        t0 = time.time()
        try:
            emit(mod.run(args.scale))
            print(f"-- {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED: {failed}")
        return 1
    print("\nall benches OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
