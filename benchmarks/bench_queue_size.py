"""Paper Fig. 11: latency–recall trade-off vs max queue size L (θ = θ₁).

L drives the greedy-phase beam for non-MI methods and the hybrid
out-range beam for ES+MI+ADAPT; ES+MI ignores it (greedy phase offloaded).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, run_method, theta_grid
from repro.core.types import TraversalConfig

QUEUE_SIZES = (8, 32, 128, 512)
METHODS = ("index", "es", "es_sws", "es_mi", "es_mi_adapt")


def run(scale: str = "ci", *, regimes=("manifold", "ood")) -> list[dict]:
    rows = []
    for regime in regimes:
        theta = theta_grid(regime, scale)[0]
        for L in QUEUE_SIZES:
            tcfg = TraversalConfig(beam_width=L, hybrid_beam=min(L, 128))
            for method in METHODS:
                res, dt, rec = run_method(regime, method, theta,
                                          scale=scale, tcfg=tcfg)
                rows.append(dict(dataset=regime, L=L, method=method,
                                 seconds=dt, recall=rec,
                                 n_dist=res.stats.n_dist))
    return rows


def main(scale: str = "ci") -> None:
    emit(run(scale))


if __name__ == "__main__":
    main()
